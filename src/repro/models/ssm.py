"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk attention-like
scores + inter-chunk linear recurrence, scanned over chunks so only one
chunk's (B, G, cl, cl) score block is live at a time.  Decode carries a
constant-size (B, H, N, P) state + a (d_conv-1)-deep conv state -- the
long_500k shape's whole point: context length never appears in decode
compute or memory.

TP sharding: the inner width (z/x projections, heads) shards over "tp";
B/C/dt projections are small and stay replicated.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import axis_if, rmsnorm, tp_ok
from repro.models.params import ParamSpec

Array = jax.Array


class SSMState(NamedTuple):
    conv: Array  # (B, d_conv - 1, conv_dim)
    ssm: Array  # (B, H, N, P)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, heads, conv_dim


def ssm_specs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    in_tp = axis_if(tp_ok(d_in), "tp")
    return {
        "w_z": ParamSpec((d, d_in), ("fsdp", in_tp), dtype=cfg.pdtype),
        "w_x": ParamSpec((d, d_in), ("fsdp", in_tp), dtype=cfg.pdtype),
        "w_b": ParamSpec((d, gn), ("fsdp", None), dtype=cfg.pdtype),
        "w_c": ParamSpec((d, gn), ("fsdp", None), dtype=cfg.pdtype),
        "w_dt": ParamSpec((d, heads), ("fsdp", None), dtype=cfg.pdtype),
        "conv_x": ParamSpec((s.d_conv, d_in), (None, in_tp), dtype=cfg.pdtype,
                            scale=0.5),
        "conv_b": ParamSpec((s.d_conv, gn), (None, None), dtype=cfg.pdtype,
                            scale=0.5),
        "conv_c": ParamSpec((s.d_conv, gn), (None, None), dtype=cfg.pdtype,
                            scale=0.5),
        "a_log": ParamSpec((heads,), (None,), dtype=jnp.float32, init="zeros"),
        "dt_bias": ParamSpec((heads,), (None,), dtype=jnp.float32,
                             init="zeros"),
        "d_skip": ParamSpec((heads,), (None,), dtype=jnp.float32, init="ones"),
        "gate_norm": ParamSpec((d_in,), (None,), dtype=jnp.float32,
                               init="ones"),
        "out_proj": ParamSpec((d_in, d), (in_tp, "fsdp"), dtype=cfg.pdtype),
    }


def _causal_conv(x: Array, kernel: Array) -> Array:
    """Depthwise causal 1-D conv.  x: (B, S, C), kernel: (K, C)."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled adds fuse well
        out = out + xp[:, i : i + x.shape[1]] * kernel[i]
    return out


def _proj_inputs(params, h, cfg):
    s = cfg.ssm
    cd = cfg.cdtype
    b, sl, _ = h.shape
    d_in, heads, _ = _dims(cfg)
    z = h @ params["w_z"].astype(cd)
    x = h @ params["w_x"].astype(cd)
    bb = h @ params["w_b"].astype(cd)
    cc = h @ params["w_c"].astype(cd)
    dt = (h @ params["w_dt"].astype(cd)).astype(jnp.float32)
    return z, x, bb, cc, dt


def ssd(
    params: dict,
    h: Array,  # (B, S, d)
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    initial_state: Array | None = None,
    return_state: bool = False,
):
    """Chunked SSD forward.  Returns (B, S, d) (+ final (B,H,N,P) state)."""
    s = cfg.ssm
    cd = cfg.cdtype
    b, sl, _ = h.shape
    d_in, heads, _ = _dims(cfg)
    g, n, p = s.n_groups, s.d_state, s.head_dim
    hg = heads // g

    z, x, bb, cc, dt = _proj_inputs(params, h, cfg)
    x = jax.nn.silu(_causal_conv(x, params["conv_x"].astype(cd)))
    bb = jax.nn.silu(_causal_conv(bb, params["conv_b"].astype(cd)))
    cc = jax.nn.silu(_causal_conv(cc, params["conv_c"].astype(cd)))
    x = constrain(x, rules, "dp", None, "tp")

    cl = min(s.chunk, sl)
    pad = (-sl) % cl
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // cl

    xh = x.reshape(b, nc, cl, heads, p)
    bh = bb.reshape(b, nc, cl, g, n)
    ch = cc.reshape(b, nc, cl, g, n)
    dt = jax.nn.softplus(dt + params["dt_bias"]).reshape(b, nc, cl, heads)
    a = -jnp.exp(params["a_log"])  # (H,) negative
    da = dt * a  # (B, nc, cl, H) log-decay per step

    def chunk_step(state, inp):
        xc, bc, cc_, dac, dtc = inp  # (B,cl,H,P) (B,cl,G,N) x2, (B,cl,H) x2
        cum = jnp.cumsum(dac, axis=1)  # (B, cl, H)
        total = cum[:, -1]  # (B, H)
        xdt = xc * dtc[..., None]  # discretized input

        # Intra-chunk (the "dual" quadratic form), f32 accumulators.
        scores = jnp.einsum("bign,bjgn->bgij", cc_.astype(jnp.float32),
                            bc.astype(jnp.float32))  # (B,G,cl,cl)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B, i, j, H)
        ii = jnp.arange(cl)
        causal = ii[:, None] >= ii[None, :]
        l_mat = jnp.where(causal[None, :, :, None], jnp.exp(decay), 0.0)
        l_mat = l_mat.reshape(b, cl, cl, g, hg)
        y_intra = jnp.einsum(
            "bgij,bijgh,bjghp->bighp",
            scores, l_mat.transpose(0, 1, 2, 3, 4),
            xdt.astype(jnp.float32).reshape(b, cl, g, hg, p),
        )

        # Inter-chunk: contribution of the carried state.
        c_dec = cc_.astype(jnp.float32)[:, :, :, None, :] * jnp.exp(
            cum
        ).reshape(b, cl, g, hg, 1)  # (B,cl,G,hg,N)
        y_inter = jnp.einsum(
            "bighn,bghnp->bighp", c_dec,
            state.reshape(b, g, hg, n, p),
        )

        # State update for the next chunk.
        b_dec = bc.astype(jnp.float32)[:, :, :, None, :] * jnp.exp(
            total[:, None, :] - cum
        ).reshape(b, cl, g, hg, 1)  # decay-to-end
        new_state = jnp.einsum(
            "bighn,bighp->bghnp", b_dec,
            xdt.astype(jnp.float32).reshape(b, cl, g, hg, p),
        ).reshape(b, heads, n, p)
        new_state = new_state + jnp.exp(total)[..., None, None] * state

        y = (y_intra + y_inter).reshape(b, cl, heads, p)
        return new_state, y.astype(cd)

    state0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, heads, n, p), jnp.float32)
    )
    xs = (
        xh.swapaxes(0, 1), bh.swapaxes(0, 1), ch.swapaxes(0, 1),
        da.swapaxes(0, 1), dt.swapaxes(0, 1),
    )
    final_state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = ys.swapaxes(0, 1).reshape(b, nc * cl, heads, p)[:, :sl]
    y = y + (params["d_skip"].astype(cd)[:, None]
             * x[:, :sl].reshape(b, sl, heads, p))

    y = y.reshape(b, sl, d_in)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps, cfg.bf16_norm_grad)
    out = y @ params["out_proj"].astype(cd)
    out = constrain(out, rules, "dp", None, None)
    if return_state:
        return out, final_state
    return out


def ssd_init_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    d_in, heads, conv_dim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), cfg.cdtype),
        ssm=jnp.zeros((batch, heads, s.d_state, s.head_dim), jnp.float32),
    )


def ssd_decode(
    params: dict,
    h: Array,  # (B, 1, d)
    state: SSMState,
    cfg: ModelConfig,
    rules: ShardingRules,
):
    """O(1)-state decode step."""
    s = cfg.ssm
    cd = cfg.cdtype
    b = h.shape[0]
    d_in, heads, conv_dim = _dims(cfg)
    g, n, p = s.n_groups, s.d_state, s.head_dim
    hg = heads // g

    z, x, bb, cc, dt = _proj_inputs(params, h, cfg)
    xbc = jnp.concatenate([x, bb, cc], axis=-1)  # (B, 1, conv_dim)
    window = jnp.concatenate([state.conv, xbc], axis=1)  # (B, d_conv, C)
    kernel = jnp.concatenate(
        [params["conv_x"], params["conv_b"], params["conv_c"]], axis=1
    ).astype(cd)
    conv_out = jax.nn.silu((window * kernel[None]).sum(axis=1))  # (B, C)
    x_t, b_t, c_t = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
    new_conv = window[:, 1:]

    dt_t = jax.nn.softplus(dt[:, 0] + params["dt_bias"])  # (B, H)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt_t * a)  # (B, H)

    x_t = x_t.reshape(b, heads, p).astype(jnp.float32)
    b_t = b_t.reshape(b, g, 1, n, 1).astype(jnp.float32)
    c_t = c_t.reshape(b, g, 1, n).astype(jnp.float32)
    inc = (
        b_t * (dt_t.reshape(b, g, hg, 1, 1) * x_t.reshape(b, g, hg, 1, p))
    ).reshape(b, heads, n, p)
    new_ssm = da[..., None, None] * state.ssm + inc
    y = jnp.einsum(
        "bgn,bghnp->bghp", c_t[:, :, 0], new_ssm.reshape(b, g, hg, n, p)
    ).reshape(b, heads, p)
    y = y + params["d_skip"][:, None] * x_t
    y = y.reshape(b, 1, d_in).astype(cd)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps, cfg.bf16_norm_grad)
    out = y @ params["out_proj"].astype(cd)
    return out, SSMState(conv=new_conv, ssm=new_ssm)
