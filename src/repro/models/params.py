"""Spec-first parameter system.

Model definitions build pytrees of :class:`ParamSpec` (shape + dtype +
initializer + PartitionSpec).  From that single source of truth we derive

* ``shape_tree``     -- ShapeDtypeStructs for ``jit(...).lower()`` dry-runs
                        (no allocation; the 512-device path),
* ``sharding_tree``  -- NamedShardings for a concrete mesh,
* ``materialize``    -- real arrays for smoke tests / examples / training.

Sharding vocabulary (see ``repro.distributed.sharding``): specs are written
with *logical* axis names ("tp", "fsdp", "sp") that are resolved to mesh
axes per run -- e.g. tp -> "model", fsdp -> ("pod", "data") -- so the same
model definition serves the single-pod, multi-pod and single-device cases.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter: shape/dtype/init plus logical sharding axes.

    ``axes`` has one entry per dim: None (replicated), or a logical axis
    name string.  ``scale`` feeds the initializer (truncated normal).
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # "normal" | "zeros" | "ones" | "embed"
    scale: float | None = None  # None => fan-in 1/sqrt(shape[-2] or [0])

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initializer(self, key: Array) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[0]
            scale = 1.0 / np.sqrt(fan_in)
        return (
            jax.random.truncated_normal(key, -2.0, 2.0, self.shape, jnp.float32)
            * scale
        ).astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map(fn: Callable[[ParamSpec], Any], tree: PyTree) -> PyTree:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def shape_tree(tree: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins (dry-run: no device allocation)."""
    return _map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)


def spec_tree(tree: PyTree, resolve: Callable[[str | None], Any]) -> PyTree:
    """PartitionSpec tree; ``resolve`` maps logical axis -> mesh axes."""
    from jax.sharding import PartitionSpec as P

    return _map(lambda p: P(*(resolve(a) for a in p.axes)), tree)


def sharding_tree(tree: PyTree, mesh, resolve) -> PyTree:
    """NamedShardings with divisibility guards: a dim whose size does not
    divide by its mesh-axis extent falls back to replicated (e.g. the
    global_batch=1 long-context cell cannot shard its batch dim)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def mesh_extent(axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return mesh.shape[axes]
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def one(p: ParamSpec) -> NamedSharding:
        resolved = []
        for size, logical in zip(p.shape, p.axes):
            axes = resolve(logical)
            if axes is not None and size % mesh_extent(axes) != 0:
                axes = None
            resolved.append(axes)
        return NamedSharding(mesh, P(*resolved))

    return _map(one, tree)


def materialize(tree: PyTree, key: Array) -> PyTree:
    """Instantiate real parameters (smoke tests, examples, real training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [p.initializer(k) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def count_params(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(p.shape)) for p in leaves)


def stack_specs(spec_fn: Callable[[], PyTree], n: int) -> PyTree:
    """Stack one layer's spec tree to (n, ...) for scan-over-layers."""

    def stack(p: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            p, shape=(n, *p.shape), axes=(None, *p.axes)
        )

    return _map(stack, spec_fn())
