"""Llama-3.2-Vision-style VLM backbone: a decoder LM with gated
cross-attention layers every ``cross.every_k_layers``-th layer.

The vision tower is a stub per the assignment: ``batch["ctx"]`` carries
precomputed patch embeddings (B, n_context_tokens, d_model).  Layers are
scanned per *group* (k-1 self layers + 1 cross layer), so depth stays O(1)
in the HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import blocks
from repro.models.layers import (
    chunked_cross_entropy,
    embed,
    embed_specs,
    rmsnorm,
    rmsnorm_spec,
    unembed_matrix,
)
from repro.models.lm import _mixer_cache_spec, _stack_cache
from repro.models.params import stack_specs

Array = jax.Array


def _group_shape(cfg: ModelConfig) -> tuple[int, int]:
    k = cfg.cross.every_k_layers
    assert cfg.n_layers % k == 0, "n_layers must divide into cross groups"
    return cfg.n_layers // k, k - 1  # (n_groups, self layers per group)


def vlm_specs(cfg: ModelConfig) -> dict:
    n_groups, n_self = _group_shape(cfg)
    group = {
        "self": stack_specs(
            lambda: blocks.layer_specs(cfg, mixer="attn", ffn="mlp"), n_self),
        "cross": blocks.layer_specs(cfg, mixer="cross", ffn="mlp"),
    }
    return {
        "embed": embed_specs(cfg),
        "groups": stack_specs(lambda: group, n_groups),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }


def vlm_cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    n_groups, n_self = _group_shape(cfg)
    group = {
        "self": _stack_cache(
            {"mixer": _mixer_cache_spec(cfg, "attn", batch, s_max)}, n_self),
        "cross": {"mixer": _mixer_cache_spec(cfg, "cross", batch, s_max)},
    }
    return _stack_cache(group, n_groups)


def _run_groups(params, x, ctx, cfg, rules, *, mode, positions=None,
                pos=None, caches=None):
    def group_fn(gp, xx, gc):
        def self_fn(p, h, c):
            return blocks.layer_apply(
                p, h, cfg=cfg, rules=rules, mixer="attn", ffn="mlp",
                mode=mode, positions=positions, pos=pos, cache=c)

        xx, aux, nc_self = blocks.scan_stack(
            self_fn, gp["self"], xx, cfg,
            cache=gc["self"] if gc is not None else None)
        xx, aux2, nc_cross = blocks.layer_apply(
            gp["cross"], xx, cfg=cfg, rules=rules, mixer="cross", ffn="mlp",
            mode=mode, positions=positions, pos=pos,
            cache=gc["cross"] if gc is not None else None, ctx=ctx)
        nc = None
        if nc_self is not None or nc_cross is not None:
            nc = {"self": nc_self, "cross": nc_cross}
        return xx, aux + aux2, nc

    return blocks.scan_stack(group_fn, params["groups"], x, cfg, cache=caches)


def vlm_loss(params, batch: dict, cfg: ModelConfig,
             rules: ShardingRules) -> tuple[Array, dict]:
    tokens, labels, ctx = batch["tokens"], batch["labels"], batch["ctx"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, aux, _ = _run_groups(params, x, ctx, cfg, rules, mode="train",
                            positions=positions)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    ce = chunked_cross_entropy(x, unembed_matrix(params["embed"]), labels,
                               cfg, rules)
    return ce + aux, {"ce": ce, "aux": aux}


def vlm_prefill(params, batch: dict, cfg: ModelConfig, rules: ShardingRules):
    tokens, ctx = batch["tokens"], batch["ctx"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, caches = _run_groups(params, x, ctx, cfg, rules, mode="prefill",
                               positions=positions)
    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], caches


def vlm_decode_step(params, tokens: Array, caches, pos: Array,
                    cfg: ModelConfig, rules: ShardingRules):
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, new_caches = _run_groups(params, x, None, cfg, rules,
                                   mode="decode", pos=pos, caches=caches)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], new_caches
