"""Dense MLP (SwiGLU, llama-style) with Megatron column/row TP sharding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import axis_if, tp_ok
from repro.models.params import ParamSpec

Array = jax.Array


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    ff_tp = axis_if(tp_ok(ff), "tp")
    return {
        "w_gate": ParamSpec((d, ff), ("fsdp", ff_tp), dtype=cfg.pdtype),
        "w_up": ParamSpec((d, ff), ("fsdp", ff_tp), dtype=cfg.pdtype),
        "w_down": ParamSpec((ff, d), (ff_tp, "fsdp"), dtype=cfg.pdtype),
    }


def mlp(params: dict, x: Array, cfg: ModelConfig, rules: ShardingRules) -> Array:
    cd = cfg.cdtype
    g = x @ params["w_gate"].astype(cd)
    u = x @ params["w_up"].astype(cd)
    h = jax.nn.silu(g) * u
    h = constrain(h, rules, "dp", None, "tp")
    y = h @ params["w_down"].astype(cd)
    return constrain(y, rules, "dp", None, None)
