"""Shared model primitives: norms, RoPE, embeddings, chunked attention math.

Everything is pjit-style: functions operate on *global* shapes; sharding is
expressed via ParamSpec logical axes plus ``constrain`` hints on
activations.  No flax -- params are plain pytrees built by each module's
``*_specs`` function (see ``repro.models.params``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.params import ParamSpec

Array = jax.Array

# The production meshes fix the tensor-parallel degree; ParamSpec axes are
# chosen statically against it (dims not divisible by TP_SIZE stay
# replicated -- e.g. whisper's 12 heads).  Single-device runs resolve every
# logical axis to None, so this constant only gates *which* dims carry the
# "tp" tag.
TP_SIZE = 16
FSDP_SIZE = 32  # pod x data in the multi-pod mesh (16 single-pod divides it)


def tp_ok(dim: int) -> bool:
    return dim % TP_SIZE == 0


def fsdp_ok(dim: int) -> bool:
    return dim % FSDP_SIZE == 0


def axis_if(cond: bool, name: str) -> str | None:
    return name if cond else None


def padded_vocab(vocab: int) -> int:
    """Pad embedding tables to a multiple of 256 (16 TP x 16 lanes)."""
    return -(-vocab // 256) * 256


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), dtype=jnp.float32, init="ones")


def rmsnorm(w: Array, x: Array, eps: float = 1e-5,
            bf16_grad: bool = False) -> Array:
    """RMSNorm, f32 internals.

    ``bf16_grad`` (EXPERIMENTS.md Sec. Perf, deepseek-67b hillclimb): the
    autodiff of the f32 upcast promotes the *residual-stream cotangent* to
    f32, which doubles every backward tensor-parallel all-reduce.  The
    custom-vjp path computes the same gradient but hands back dx in x's
    own dtype (bf16), halving those collective bytes; dw stays f32.
    """
    if bf16_grad:
        return _rmsnorm_vjp(w, x, eps)
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_vjp(w: Array, x: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(dt)


def _rmsnorm_fwd(w, x, eps):
    return _rmsnorm_vjp(w, x, eps), (w, x)


def _rmsnorm_bwd(eps, res, dy):
    w, x = res
    d = x.shape[-1]
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    g = dy.astype(jnp.float32) * w  # (.., d)
    xg = jnp.sum(xf * g, axis=-1, keepdims=True)
    dx = r * g - (r**3 / d) * xf * xg
    dw = jnp.sum(dy.astype(jnp.float32) * xf * r,
                 axis=tuple(range(x.ndim - 1)))
    return dw, dx.astype(x.dtype)  # dx cast back: bf16 collective bytes


_rmsnorm_vjp.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig) -> dict:
    pv = padded_vocab(cfg.vocab)
    spec = {
        "table": ParamSpec(
            (pv, cfg.d_model),
            ("tp", axis_if(fsdp_ok(cfg.d_model), "fsdp")),
            dtype=cfg.pdtype,
            scale=1.0,
        )
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = ParamSpec(
            (cfg.d_model, pv),
            (axis_if(fsdp_ok(cfg.d_model), "fsdp"), "tp"),
            dtype=cfg.pdtype,
        )
    return spec


def embed(params: dict, tokens: Array, cfg: ModelConfig,
          rules: ShardingRules) -> Array:
    x = jnp.take(params["table"], tokens, axis=0).astype(cfg.cdtype)
    return constrain(x, rules, "dp", None, None)


def unembed_matrix(params: dict) -> Array:
    if "unembed" in params:
        return params["unembed"]
    return params["table"].T


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (never materializes full (B, S, V) logits)
# ---------------------------------------------------------------------------
def chunked_cross_entropy(
    x: Array,  # (B, S, d) final hidden states
    w_unembed: Array,  # (d, V)
    labels: Array,  # (B, S) int32
    cfg: ModelConfig,
    rules: ShardingRules,
) -> Array:
    """Mean CE over all positions, computed in sequence chunks so the peak
    logits buffer is (B, ce_chunk, V) instead of (B, S, V)."""
    b, s, d = x.shape
    ck = min(cfg.ce_chunk, s)
    # Pad so the sequence divides evenly; padded positions get weight 0.
    pad = (-s) % ck
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // ck
    xs = x.reshape(b, nc, ck, d).swapaxes(0, 1)  # (nc, B, ck, d)
    ls = labels.reshape(b, nc, ck).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        xc, lc = inp
        logits = (xc @ w_unembed.astype(xc.dtype)).astype(jnp.float32)
        logits = constrain(logits, rules, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        valid = lc >= 0
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xs, ls),
    )
    return total / jnp.maximum(count, 1).astype(jnp.float32)
