"""Jamba-style hybrid stack: 1 attention layer per ``attn_period`` layers
(the rest are Mamba SSD mixers), FFN alternating dense MLP / MoE.

Layers are scanned per *period group* (one group = ``attn_period`` layers
with a fixed mixer/ffn pattern), keeping the HLO O(1) in depth: the 72-layer
Jamba lowers as 9 scanned groups of 8 distinct layer bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import blocks
from repro.models.layers import (
    chunked_cross_entropy,
    embed,
    embed_specs,
    rmsnorm,
    rmsnorm_spec,
    unembed_matrix,
)
from repro.models.lm import _mixer_cache_spec, _stack_cache
from repro.models.params import stack_specs

Array = jax.Array


def _pattern(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(mixer, ffn) for each layer inside one period group.

    Attention sits mid-period (Jamba places it at offset period//2); MoE on
    odd global layer indices (= odd in-group indices, since the period is
    even)."""
    period = cfg.attn_period
    attn_at = period // 2
    out = []
    for i in range(period):
        mixer = "attn" if i == attn_at else "ssm"
        ffn = "moe" if (cfg.moe and i % cfg.moe.every_k_layers
                        == cfg.moe.every_k_layers - 1) else "mlp"
        out.append((mixer, ffn))
    return out


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_period == 0
    return cfg.n_layers // cfg.attn_period


def hybrid_specs(cfg: ModelConfig) -> dict:
    pattern = _pattern(cfg)
    group = {
        f"layer{i}": blocks.layer_specs(cfg, mixer=m, ffn=f)
        for i, (m, f) in enumerate(pattern)
    }
    return {
        "embed": embed_specs(cfg),
        "groups": stack_specs(lambda: group, _n_groups(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }


def hybrid_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    group = {
        f"layer{i}": {"mixer": _mixer_cache_spec(cfg, m, batch, s_max)}
        for i, (m, _) in enumerate(_pattern(cfg))
    }
    return _stack_cache(group, _n_groups(cfg))


def _run_groups(params, x, cfg, rules, *, mode, positions=None, pos=None,
                caches=None):
    pattern = _pattern(cfg)

    def group_fn(gp, xx, gc):
        aux_total = jnp.zeros((), jnp.float32)
        nc = {}
        for i, (mixer, ffn) in enumerate(pattern):
            xx, aux, c = blocks.layer_apply(
                gp[f"layer{i}"], xx, cfg=cfg, rules=rules, mixer=mixer,
                ffn=ffn, mode=mode, positions=positions, pos=pos,
                cache=gc[f"layer{i}"] if gc is not None else None)
            aux_total = aux_total + aux
            nc[f"layer{i}"] = c
        return xx, aux_total, (nc if any(v is not None for v in nc.values())
                               else None)

    return blocks.scan_stack(group_fn, params["groups"], x, cfg, cache=caches)


def hybrid_loss(params, batch: dict, cfg: ModelConfig,
                rules: ShardingRules) -> tuple[Array, dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, aux, _ = _run_groups(params, x, cfg, rules, mode="train",
                            positions=positions)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    ce = chunked_cross_entropy(x, unembed_matrix(params["embed"]), labels,
                               cfg, rules)
    return ce + aux, {"ce": ce, "aux": aux}


def hybrid_prefill(params, batch: dict, cfg: ModelConfig,
                   rules: ShardingRules):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, caches = _run_groups(params, x, cfg, rules, mode="prefill",
                               positions=positions)
    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], caches


def hybrid_decode_step(params, tokens: Array, caches, pos: Array,
                       cfg: ModelConfig, rules: ShardingRules):
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, new_caches = _run_groups(params, x, cfg, rules, mode="decode",
                                   pos=pos, caches=caches)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], new_caches
