"""Attention: GQA (train/prefill/decode), cross-attention, and MLA.

Memory policy: scores are never materialized at (B, H, S, S).  Training and
prefill use *chunked-query* attention (scan over query blocks of
``cfg.q_chunk``); decode masks over the cache with the sequence dim sharded
across the "sp" (=model) mesh axis, so XLA reduces the softmax and the
probs-V contraction with small (B, H)-sized collectives (flash-decoding
layout, DESIGN.md Sec. 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import apply_rope, axis_if, rmsnorm, rmsnorm_spec, tp_ok
from repro.models.params import ParamSpec

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q_tp = axis_if(tp_ok(h * hd), "tp")
    kv_tp = axis_if(tp_ok(kv * hd), "tp")
    return {
        "wq": ParamSpec((d, h * hd), ("fsdp", q_tp), dtype=cfg.pdtype),
        "wk": ParamSpec((d, kv * hd), ("fsdp", kv_tp), dtype=cfg.pdtype),
        "wv": ParamSpec((d, kv * hd), ("fsdp", kv_tp), dtype=cfg.pdtype),
        "wo": ParamSpec((h * hd, d), (q_tp, "fsdp"), dtype=cfg.pdtype),
    }


def mla_specs(cfg: ModelConfig) -> dict:
    mla = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = mla.qk_nope_dim + mla.qk_rope_dim
    return {
        "wq_a": ParamSpec((d, mla.q_lora_rank), ("fsdp", None), dtype=cfg.pdtype),
        "q_norm": rmsnorm_spec(mla.q_lora_rank),
        "wq_b": ParamSpec(
            (mla.q_lora_rank, h * qd), (None, "tp"), dtype=cfg.pdtype
        ),
        "wkv_a": ParamSpec(
            (d, mla.kv_lora_rank + mla.qk_rope_dim), ("fsdp", None),
            dtype=cfg.pdtype,
        ),
        "kv_norm": rmsnorm_spec(mla.kv_lora_rank),
        "wkv_b": ParamSpec(
            (mla.kv_lora_rank, h * (mla.qk_nope_dim + mla.v_head_dim)),
            (None, "tp"), dtype=cfg.pdtype,
        ),
        "wo": ParamSpec(
            (h * mla.v_head_dim, d), ("tp", "fsdp"), dtype=cfg.pdtype
        ),
    }


# ---------------------------------------------------------------------------
# Core chunked SDPA (full-head layout)
# ---------------------------------------------------------------------------
def _sdpa_chunked(
    q: Array,  # (B, S_q, H, hd)
    k: Array,  # (B, S_k, H, hd)  -- GQA KV already repeated to H heads
    v: Array,  # (B, S_k, H, hd)
    *,
    causal: bool,
    q_chunk: int,
    scale: float,
    rules: ShardingRules | None = None,
    head_tp: bool = False,
) -> Array:
    """Exact attention, scanned over query chunks; scores peak at
    (B, H, q_chunk, S_k).

    Everything stays in full-head (H) layout: a (kv, group) split would
    break the tensor-parallel head sharding whenever neither factor
    divides the TP degree (e.g. kv=4, g=8 on a 16-way axis), forcing XLA
    to replicate the score tensor.  Repeating KV to H heads is local
    (the KV source is TP-replicated), so no collective is introduced.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    ck = min(q_chunk, sq)
    pad = (-sq) % ck
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // ck
    qs = q.reshape(b, nc, ck, h, hd).transpose(1, 0, 2, 3, 4)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    tp_axis = "tp" if head_tp else None

    # Per-chunk remat: without it the scan's transpose stacks the f32
    # probs of EVERY chunk ((nc, B, H, ck, S_k) -- gigabytes per layer);
    # rematerializing one chunk's scores in backward is the flash-attention
    # memory behaviour at ~1/3 extra attention flops.
    @jax.checkpoint
    def one_chunk_body(c, qc):
        qf = qc.astype(jnp.float32) * scale
        scores = jnp.einsum("bqhd,bshd->bhqs", qf, kf)
        if rules is not None:
            scores = constrain(scores, rules, "dp", tp_axis, None, None)
        if causal:
            rows = c * ck + jnp.arange(ck)
            mask = rows[:, None] >= jnp.arange(sk)[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, vf)
        return out.astype(q.dtype)

    def one_chunk(c, qc):
        return c + 1, one_chunk_body(c, qc)

    _, outs = jax.lax.scan(one_chunk, 0, qs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * ck, h, hd)
    return out[:, :sq]


def repeat_kv(x: Array, n_rep: int) -> Array:
    """(B, S, KV, hd) -> (B, S, KV * n_rep, hd), GQA group-expansion."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return x.reshape(b, s, kv * n_rep, hd)


def _split_heads(x: Array, n: int, hd: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


# ---------------------------------------------------------------------------
# GQA attention: train / prefill
# ---------------------------------------------------------------------------
def attention(
    params: dict,
    x: Array,  # (B, S, d)
    positions: Array,  # (B, S)
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    causal: bool = True,
    ctx: Array | None = None,  # (B, T, d) for cross-attention
    return_cache: bool = False,
    allow_flash: bool = False,  # prefill/serving only (kernel has no VJP on TPU)
):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    cd = cfg.cdtype
    kv_src = x if ctx is None else ctx

    q = _split_heads(x @ params["wq"].astype(cd), h, hd)
    k = _split_heads(kv_src @ params["wk"].astype(cd), kv, hd)
    v = _split_heads(kv_src @ params["wv"].astype(cd), kv, hd)
    if ctx is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k_cache, v_cache = k, v  # cache stores the un-repeated KV heads
    head_tp = tp_ok(h * hd)
    tp_axis = "tp" if head_tp else None
    q = constrain(q, rules, "dp", None, tp_axis, None)
    k = constrain(repeat_kv(k, g), rules, "dp", None, tp_axis, None)
    v = constrain(repeat_kv(v, g), rules, "dp", None, tp_axis, None)

    b, s, _, _ = q.shape
    if allow_flash and cfg.flash_attention:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=causal and ctx is None,
                              scale=1.0 / float(hd) ** 0.5)
    else:
        out = _sdpa_chunked(
            q, k, v,
            causal=causal and ctx is None,
            q_chunk=cfg.q_chunk,
            scale=1.0 / float(hd) ** 0.5,
            rules=rules,
            head_tp=head_tp,
        )
    y = out.reshape(b, s, h * hd) @ params["wo"].astype(cd)
    y = constrain(y, rules, "dp", None, None)
    if return_cache:
        return y, (k_cache, v_cache)
    return y


# ---------------------------------------------------------------------------
# GQA attention: decode (one new token against a seq-sharded cache)
# ---------------------------------------------------------------------------
def attention_decode(
    params: dict,
    x: Array,  # (B, 1, d)
    cache_k: Array,  # (B, S_max, KV, hd)  -- sharded P(dp, sp, ., .)
    cache_v: Array,
    pos: Array,  # scalar int32: current length (same for the batch)
    cfg: ModelConfig,
    rules: ShardingRules,
):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    cd = cfg.cdtype
    b = x.shape[0]
    s_max = cache_k.shape[1]

    positions = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(_split_heads(x @ params["wq"].astype(cd), h, hd),
                   positions, cfg.rope_theta)
    k_new = apply_rope(_split_heads(x @ params["wk"].astype(cd), kv, hd),
                       positions, cfg.rope_theta)
    v_new = _split_heads(x @ params["wv"].astype(cd), kv, hd)

    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), pos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), pos, 1)
    cache_k = constrain(cache_k, rules, "dp", "sp", None, None)
    cache_v = constrain(cache_v, rules, "dp", "sp", None, None)

    qf = q.reshape(b, 1, kv, g, hd).astype(jnp.float32) / float(hd) ** 0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, cache_k.astype(jnp.float32))
    mask = jnp.arange(s_max) <= pos
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    scores = constrain(scores, rules, "dp", None, None, None, "sp")
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache_v.astype(jnp.float32))
    y = out.astype(cd).reshape(b, 1, h * hd) @ params["wo"].astype(cd)
    return y, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): train + absorbed decode over the latent cache
# ---------------------------------------------------------------------------
def _mla_qkv(params, x, positions, cfg):
    """Shared projections (train path, non-absorbed)."""
    mla, h = cfg.mla, cfg.n_heads
    cd = cfg.cdtype
    b, s, _ = x.shape
    qd = mla.qk_nope_dim + mla.qk_rope_dim

    q = rmsnorm(params["q_norm"], x @ params["wq_a"].astype(cd), cfg.norm_eps, cfg.bf16_norm_grad)
    q = (q @ params["wq_b"].astype(cd)).reshape(b, s, h, qd)
    q_nope, q_rope = jnp.split(q, [mla.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"].astype(cd)
    c_kv, k_rope = jnp.split(kv_a, [mla.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps, cfg.bf16_norm_grad)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_attention(
    params: dict,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    return_cache: bool = False,
):
    """Training / prefill MLA: per-head K/V decoded from the latent."""
    mla, h = cfg.mla, cfg.n_heads
    cd = cfg.cdtype
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)

    wkv_b = params["wkv_b"].astype(cd).reshape(
        mla.kv_lora_rank, h, mla.qk_nope_dim + mla.v_head_dim
    )
    w_uk, w_uv = jnp.split(wkv_b, [mla.qk_nope_dim], axis=-1)
    k_nope = jnp.einsum("bsk,khn->bshn", c_kv, w_uk)
    v = jnp.einsum("bsk,khv->bshv", c_kv, w_uv)

    # Chunked over queries, exactly like GQA but with split nope/rope scores.
    ck = min(cfg.q_chunk, s)
    pad = (-s) % ck
    qn = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = qn.shape[1] // ck
    scale = 1.0 / float(mla.qk_nope_dim + mla.qk_rope_dim) ** 0.5
    kf, rf, vf = (t.astype(jnp.float32) for t in (k_nope, k_rope, v))

    def one_chunk(c, inp):
        qnc, qrc = inp
        sc = jnp.einsum("bqhn,bshn->bhqs", qnc.astype(jnp.float32), kf)
        sc += jnp.einsum("bqhr,bsr->bhqs", qrc.astype(jnp.float32), rf)
        rows = c * ck + jnp.arange(ck)
        mask = rows[:, None] >= jnp.arange(s)[None, :]
        sc = jnp.where(mask[None, None], sc * scale, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bhqs,bshv->bqhv", probs, vf)
        return c + 1, out.astype(cd)

    _, outs = jax.lax.scan(
        one_chunk, 0,
        (qn.reshape(b, nc, ck, h, -1).swapaxes(0, 1),
         qr.reshape(b, nc, ck, h, -1).swapaxes(0, 1)),
    )
    out = outs.swapaxes(0, 1).reshape(b, nc * ck, h, mla.v_head_dim)[:, :s]
    y = out.reshape(b, s, h * mla.v_head_dim) @ params["wo"].astype(cd)
    if return_cache:
        return y, (c_kv, k_rope)
    return y


def mla_attention_decode(
    params: dict,
    x: Array,  # (B, 1, d)
    cache_ckv: Array,  # (B, S_max, kv_lora)  latent cache (the MLA win)
    cache_rope: Array,  # (B, S_max, rope_dim)
    pos: Array,
    cfg: ModelConfig,
    rules: ShardingRules,
):
    """Absorbed decode: queries are mapped into the latent space, so the
    cache stays at kv_lora + rope_dim per token."""
    mla, h = cfg.mla, cfg.n_heads
    cd = cfg.cdtype
    b = x.shape[0]
    s_max = cache_ckv.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)

    q_nope, q_rope, c_new, r_new = _mla_qkv(params, x, positions, cfg)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_new.astype(cache_ckv.dtype), pos, 1)
    cache_rope = jax.lax.dynamic_update_slice_in_dim(
        cache_rope, r_new.astype(cache_rope.dtype), pos, 1)
    cache_ckv = constrain(cache_ckv, rules, "dp", "sp", None)
    cache_rope = constrain(cache_rope, rules, "dp", "sp", None)

    wkv_b = params["wkv_b"].astype(cd).reshape(
        mla.kv_lora_rank, h, mla.qk_nope_dim + mla.v_head_dim
    )
    w_uk, w_uv = jnp.split(wkv_b, [mla.qk_nope_dim], axis=-1)
    # Absorb W_uk into the query: q_lat (B, 1, H, kv_lora).
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, w_uk)

    scale = 1.0 / float(mla.qk_nope_dim + mla.qk_rope_dim) ** 0.5
    sc = jnp.einsum("bqhk,bsk->bhqs", q_lat.astype(jnp.float32),
                    cache_ckv.astype(jnp.float32))
    sc += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                     cache_rope.astype(jnp.float32))
    mask = jnp.arange(s_max) <= pos
    sc = jnp.where(mask[None, None, None], sc * scale, NEG_INF)
    sc = constrain(sc, rules, "dp", None, None, "sp")
    probs = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhqs,bsk->bqhk", probs,
                       cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bqhk,khv->bqhv", o_lat.astype(cd), w_uv)
    y = out.reshape(b, 1, h * mla.v_head_dim) @ params["wo"].astype(cd)
    return y, (cache_ckv, cache_rope)
