"""Decoder-only LM assembly for the uniform-stack families:

  dense (deepseek-67b / yi-6b / llama3-8b / tinyllama),
  moe   (qwen2-moe; deepseek-v2 = MLA mixer + leading dense layers),
  ssm   (mamba2 -- attention-free).

Heterogeneous families (vlm / encdec / hybrid) build on the same blocks in
their own modules.  The stack is described by ``stack_plan`` segments; each
segment is a scanned homogeneous run of layers.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import blocks
from repro.models.layers import (
    chunked_cross_entropy,
    embed,
    embed_specs,
    padded_vocab,
    rmsnorm,
    rmsnorm_spec,
    unembed_matrix,
)
from repro.models.params import ParamSpec, stack_specs

Array = jax.Array


class Segment(NamedTuple):
    mixer: str
    ffn: str
    count: int


def stack_plan(cfg: ModelConfig) -> list[Segment]:
    if cfg.family == "dense":
        return [Segment("attn", "mlp", cfg.n_layers)]
    if cfg.family == "ssm":
        return [Segment("ssm", "none", cfg.n_layers)]
    if cfg.family == "moe":
        mixer = "mla" if cfg.mla is not None else "attn"
        first = cfg.moe.first_dense
        segs = []
        if first:
            segs.append(Segment(mixer, "mlp", first))
        segs.append(Segment(mixer, "moe", cfg.n_layers - first))
        return segs
    raise ValueError(f"stack_plan: unsupported family {cfg.family}")


def lm_specs(cfg: ModelConfig) -> dict:
    segs = stack_plan(cfg)
    return {
        "embed": embed_specs(cfg),
        "segments": [
            stack_specs(
                lambda m=s.mixer, f=s.ffn: blocks.layer_specs(
                    cfg, mixer=m, ffn=f),
                s.count,
            )
            for s in segs
        ],
        "ln_f": rmsnorm_spec(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Cache specs (ParamSpec trees -> reuse shape/sharding machinery)
# ---------------------------------------------------------------------------
def _mixer_cache_spec(cfg: ModelConfig, mixer: str, batch: int,
                      s_max: int) -> Any:
    cd = cfg.cdtype
    if mixer == "attn":
        kv_spec = ParamSpec(
            (batch, s_max, cfg.n_kv_heads, cfg.hd),
            ("dp", "sp", None, None), dtype=cd, init="zeros")
        return (kv_spec, kv_spec)
    if mixer == "mla":
        return (
            ParamSpec((batch, s_max, cfg.mla.kv_lora_rank),
                      ("dp", "sp", None), dtype=cd, init="zeros"),
            ParamSpec((batch, s_max, cfg.mla.qk_rope_dim),
                      ("dp", "sp", None), dtype=cd, init="zeros"),
        )
    if mixer == "ssm":
        from repro.models.ssm import SSMState, _dims

        d_in, heads, conv_dim = _dims(cfg)
        s = cfg.ssm
        return SSMState(
            conv=ParamSpec((batch, s.d_conv - 1, conv_dim),
                           ("dp", None, None), dtype=cd, init="zeros"),
            ssm=ParamSpec((batch, heads, s.d_state, s.head_dim),
                          ("dp", None, None, None), dtype=jnp.float32,
                          init="zeros"),
        )
    if mixer == "cross":
        t = _ctx_len(cfg)
        kv_spec = ParamSpec(
            (batch, t, cfg.n_kv_heads, cfg.hd),
            ("dp", None, None, None), dtype=cd, init="zeros")
        return (kv_spec, kv_spec)
    raise ValueError(mixer)


def _ctx_len(cfg: ModelConfig) -> int:
    if cfg.cross is not None:
        return cfg.cross.n_context_tokens
    if cfg.encdec is not None:
        return cfg.encdec.n_context_tokens
    raise ValueError("no context config")


def _stack_cache(spec: Any, n: int) -> Any:
    import dataclasses

    from repro.models.params import is_spec

    return jax.tree.map(
        lambda p: dataclasses.replace(
            p, shape=(n, *p.shape), axes=(None, *p.axes)),
        spec, is_leaf=is_spec,
    )


def lm_cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> list:
    return [
        _stack_cache({"mixer": _mixer_cache_spec(cfg, s.mixer, batch, s_max)},
                     s.count)
        for s in stack_plan(cfg)
    ]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _run_segments(params, x, cfg, rules, *, mode, positions=None, pos=None,
                  caches=None):
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, seg in enumerate(stack_plan(cfg)):
        def layer_fn(p, xx, c, seg=seg):
            return blocks.layer_apply(
                p, xx, cfg=cfg, rules=rules, mixer=seg.mixer, ffn=seg.ffn,
                mode=mode, positions=positions, pos=pos, cache=c)

        cache_i = caches[i] if caches is not None else None
        x, aux, nc = blocks.scan_stack(
            layer_fn, params["segments"][i], x, cfg, cache=cache_i,
            length=seg.count)
        aux_total = aux_total + aux
        new_caches.append(nc)
    return x, aux_total, new_caches


def lm_loss(params, batch: dict, cfg: ModelConfig,
            rules: ShardingRules) -> tuple[Array, dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, aux, _ = _run_segments(params, x, cfg, rules, mode="train",
                              positions=positions)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    ce = chunked_cross_entropy(x, unembed_matrix(params["embed"]), labels,
                               cfg, rules)
    return ce + aux, {"ce": ce, "aux": aux}


def lm_prefill(params, batch: dict, cfg: ModelConfig,
               rules: ShardingRules):
    """Forward over the prompt; returns (last-position logits, caches)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, caches = _run_segments(params, x, cfg, rules, mode="prefill",
                                 positions=positions)
    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], caches


def lm_decode_step(params, tokens: Array, caches, pos: Array,
                   cfg: ModelConfig, rules: ShardingRules):
    """One decode step.  tokens: (B, 1); pos: scalar current length."""
    x = embed(params["embed"], tokens, cfg, rules)
    x, _, new_caches = _run_segments(params, x, cfg, rules, mode="decode",
                                     pos=pos, caches=caches)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps, cfg.bf16_norm_grad)
    logits = x @ unembed_matrix(params["embed"]).astype(x.dtype)
    return logits[:, 0], new_caches
