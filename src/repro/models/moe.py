"""Mixture-of-Experts FFN with two dispatch strategies.

``grouped`` (train/prefill default)
    GShard/MaxText-style capacity dispatch with the *batch row as the
    dispatch group*: ranks within (row, expert) come from a cumsum over the
    sequence dim only, so no collective crosses the data-parallel batch
    sharding.  Expert compute is one dense einsum over a (B, E, cap, d)
    buffer -- FLOPs are honest (capacity_factor x useful), every expert
    weight is read exactly once, and everything lowers on any backend.

``gather`` (decode default)
    Per-token expert-weight gather: for one-token-per-row shapes the
    capacity buffer would waste E/top_k x FLOPs; instead we gather the
    top-k experts' weights per token and contract exactly the useful FLOPs
    (weight bytes read scale with B*top_k -- honest while B*top_k <~ E,
    noted in EXPERIMENTS.md Sec. Roofline otherwise).

Shared experts (Qwen/DeepSeek style) are a plain dense MLP added to the
routed output.  Router aux loss is Switch-style load balancing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import axis_if, tp_ok
from repro.models.mlp import mlp, mlp_specs
from repro.models.params import ParamSpec

Array = jax.Array


def _use_ep(cfg: ModelConfig) -> bool:
    from repro.models.layers import TP_SIZE

    return bool(cfg.moe_ep) and cfg.moe.num_experts % TP_SIZE == 0


def moe_specs(cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, ff, e = cfg.d_model, moe.d_ff_expert, moe.num_experts
    if _use_ep(cfg):
        # Expert parallelism: experts sharded over the model axis; each
        # rank holds E/TP full experts (FSDP on d would make XLA contract
        # over the dp-sharded dim -- TB-scale all-reduces).
        w_axes_up = ("ep", None, None)
        w_axes_down = ("ep", None, None)
    else:
        ff_tp = axis_if(tp_ok(ff), "tp")
        w_axes_up = (None, "fsdp", ff_tp)
        w_axes_down = (None, ff_tp, "fsdp")
    spec = {
        "router": ParamSpec((d, e), (None, None), dtype=jnp.float32),
        "w_gate": ParamSpec((e, d, ff), w_axes_up, dtype=cfg.pdtype),
        "w_up": ParamSpec((e, d, ff), w_axes_up, dtype=cfg.pdtype),
        "w_down": ParamSpec((e, ff, d), w_axes_down, dtype=cfg.pdtype),
    }
    if moe.num_shared:
        spec["shared"] = mlp_specs(cfg, d_ff=moe.d_ff_shared)
    return spec


def _route(params, x, cfg):
    """Top-k routing.  x: (B, S, d) -> (weights, ids) (B, S, k) + aux loss."""
    moe = cfg.moe
    logits = (x.astype(jnp.float32) @ params["router"])  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, moe.top_k)  # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux: E * mean_e(frac_tokens_e * mean_prob_e)
    num = moe.num_experts
    counts = jnp.zeros((num,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tok = counts / jnp.maximum(counts.sum(), 1.0)
    frac_prob = probs.mean(axis=(0, 1))
    aux = num * jnp.sum(frac_tok * frac_prob) * moe.router_aux_weight
    return w.astype(x.dtype), ids, aux


def _moe_grouped(params, x, w, ids, cfg, rules):
    """Capacity dispatch, group = batch row."""
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    cap = max(8, int(s * k / e * moe.capacity_factor + 0.999) // 8 * 8)

    flat_ids = ids.reshape(b, s * k)  # assignments in seq-major order
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (B, S*k, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot  # rank of each assignment
    rank = jnp.take_along_axis(
        ranks, flat_ids[..., None], axis=-1)[..., 0]  # (B, S*k)
    keep = rank < cap
    # Dropped assignments go to per-assignment trash slots so that every
    # scatter index is UNIQUE -- this lets XLA use the direct scatter
    # lowering; a shared overflow slot makes indices non-unique and the
    # SPMD scatter expander falls back to a sort/permute path with
    # TB-scale collectives (EXPERIMENTS.md Sec. Perf iteration 2).
    trash = e * cap + jnp.arange(s * k)
    slot = jnp.where(keep, flat_ids * cap + rank, trash)

    xk = jnp.repeat(x, k, axis=1)  # (B, S*k, d) token per assignment
    buf = jnp.zeros((b, e * cap + s * k, d), x.dtype)
    buf = jax.vmap(
        lambda row, sl, val: row.at[sl].set(
            val, unique_indices=True, mode="promise_in_bounds")
    )(buf, slot, xk)
    buf = buf[:, : e * cap].reshape(b, e, cap, d)
    ep = _use_ep(cfg)
    # EP: the token scatter/gather stays tp-replicated (sharding the buffer
    # on E makes XLA reshard the scatter -- measured 8x worse, see
    # EXPERIMENTS.md Sec. Perf iteration 1); only the expert COMPUTE is
    # E-sharded, with one explicit all-gather of the expert outputs.
    buf = constrain(buf, rules, "dp", None, None, None)

    cd = cfg.cdtype
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    h = constrain(h, rules, "dp", "ep" if ep else None, None,
                  None if ep else "tp")
    out = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(cd))
    # (EP: `out` stays E-sharded; the slot gather below partitions into a
    # local masked gather + one (B, S*k, d) all-reduce -- 15x less traffic
    # than all-gathering the (B, E, cap, d) buffer.  Perf iteration 4.)

    # Gather back and combine with routing weights (dropped tokens -> 0;
    # trash-slot reads are masked by `keep`).
    out_flat = out.reshape(b, e * cap, d)
    safe_slot = jnp.minimum(slot, e * cap - 1)
    y = jax.vmap(
        lambda rows, sl: rows.at[sl].get(mode="promise_in_bounds")
    )(out_flat, safe_slot)  # (B, S*k, d)
    y = y * (w.reshape(b, s * k, 1) * keep[..., None]).astype(y.dtype)
    return y.reshape(b, s, k, d).sum(axis=2)


def _moe_gather(params, x, w, ids, cfg, rules):
    """Per-token expert gather (decode shapes)."""
    b, s, d = x.shape
    cd = cfg.cdtype
    xt = x.reshape(b * s, d)
    idt = ids.reshape(b * s, -1)  # (T, k)
    wt = w.reshape(b * s, -1)

    wg = jnp.take(params["w_gate"], idt, axis=0).astype(cd)  # (T, k, d, f)
    wu = jnp.take(params["w_up"], idt, axis=0).astype(cd)
    wd = jnp.take(params["w_down"], idt, axis=0).astype(cd)  # (T, k, f, d)
    g = jnp.einsum("td,tkdf->tkf", xt, wg)
    u = jnp.einsum("td,tkdf->tkf", xt, wu)
    h = jax.nn.silu(g) * u
    h = constrain(h, rules, "dp", None, "tp")
    out = jnp.einsum("tkf,tkfd->tkd", h, wd)
    y = (out * wt[..., None].astype(out.dtype)).sum(axis=1)
    return y.reshape(b, s, d)


def moe_ffn(
    params: dict,
    x: Array,
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    dispatch: str | None = None,  # None => by shape (S==1 -> gather)
) -> tuple[Array, Array]:
    """Returns (y, aux_loss)."""
    if dispatch is None:
        dispatch = "gather" if x.shape[1] == 1 else "grouped"
    w, ids, aux = _route(params, x, cfg)
    if dispatch == "grouped":
        y = _moe_grouped(params, x, w, ids, cfg, rules)
    else:
        y = _moe_gather(params, x, w, ids, cfg, rules)
    if cfg.moe.num_shared:
        y = y + mlp(params["shared"], x, cfg, rules)
    return y, aux
