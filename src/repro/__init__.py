"""repro: a production-scale reproduction of Distributed Robust PCA.

Public surface:

``repro.rpca``   the front door -- :func:`repro.rpca.solve` over the
                 solver registry, with :class:`~repro.rpca.RPCASpec` /
                 :class:`~repro.rpca.RPCAResult`.
``repro.core``   solver internals (runtime, problems, metrics, the four
                 solver modules and their legacy entrypoints).
"""
from repro import rpca
from repro.rpca import (
    RPCAResult,
    RPCASpec,
    SOLVERS,
    SolverCaps,
    auto_method,
    register_solver,
    solve,
)

__all__ = [
    "rpca",
    "RPCAResult",
    "RPCASpec",
    "SOLVERS",
    "SolverCaps",
    "auto_method",
    "register_solver",
    "solve",
]
