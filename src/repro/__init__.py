"""repro: a production-scale reproduction of Distributed Robust PCA.

Public surface:

``repro.rpca``   the front door -- :func:`repro.rpca.solve` over the
                 solver registry, with :class:`~repro.rpca.RPCASpec` /
                 :class:`~repro.rpca.RPCAResult`.
``repro.core``   solver internals (runtime, problems, metrics, the four
                 solver modules and their legacy entrypoints).
``repro.serving``  the serving plane -- ``RPCAGateway`` (async
                 continuous-batching front end) over ``RPCAService``
                 (the slot table), with the ``CapacityError`` /
                 ``QueueFull`` admission taxonomy.  Lazy (PEP 562):
                 importing ``repro`` does not pull in the serving stack.
"""
from repro import rpca
from repro.rpca import (
    RPCAResult,
    RPCASpec,
    SOLVERS,
    SolverCaps,
    auto_method,
    register_solver,
    solve,
)

__all__ = [
    "rpca",
    "RPCAResult",
    "RPCASpec",
    "SOLVERS",
    "SolverCaps",
    "auto_method",
    "register_solver",
    "solve",
    "CapacityError",
    "QueueFull",
    "GatewayConfig",
    "RPCAGateway",
    "RPCAService",
    "RPCAServiceConfig",
]

_SERVING_EXPORTS = {
    "CapacityError": ("repro.core.validate", "CapacityError"),
    "QueueFull": ("repro.core.validate", "QueueFull"),
    "GatewayConfig": ("repro.serving.gateway", "GatewayConfig"),
    "RPCAGateway": ("repro.serving.gateway", "RPCAGateway"),
    "RPCAService": ("repro.serving.rpca_service", "RPCAService"),
    "RPCAServiceConfig": ("repro.serving.rpca_service", "RPCAServiceConfig"),
}


def __getattr__(name: str):
    target = _SERVING_EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(target[0]), target[1])
    globals()[name] = value  # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_SERVING_EXPORTS))
